"""Device-resident decode hot path: fused K-step dispatch parity, bucketed
prefill compile counts, on-device done masks, cancel state hygiene, and the
dispatch/sync reduction the benchmark reports."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           RequestState, SamplingParams, Scheduler,
                           SchedulerConfig, sample_batched)
from repro.serving.request import CODE_INVALID_REQUEST
from repro.serving.sampler import sample


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["olmo-1b"].reduced()


@pytest.fixture(scope="module")
def params(cfg, param_store):
    return param_store(cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 48)
    return InferenceEngine(cfg, params, EngineConfig(**kw))


def _run(eng, reqs):
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_done()
    return [tuple(r.output) for r in reqs]


# ------------------- fused multi-token parity ---------------------- #
def test_fused_greedy_parity_k1_vs_k8(cfg, params):
    """Greedy decode must be bit-identical whether the engine dispatches
    1 or 8 steps per fused block (the K quantum is a scheduling choice,
    never a numerics choice)."""
    def work():
        return [Request(model="m", prompt=list(range(1, 2 + i)),
                        sampling=SamplingParams(max_tokens=6 + i))
                for i in range(5)]
    outs = {k: _run(_engine(cfg, params, decode_block=k), work())
            for k in (1, 4, 8)}
    assert outs[1] == outs[4] == outs[8]
    assert all(len(o) == 6 + i for i, o in enumerate(outs[8]))


def test_done_mask_stops_slots_mid_block(cfg, params):
    """Slots hitting max_tokens mid-scan stop advancing on device: exact
    budgets even when they are not multiples of the K quantum."""
    eng = _engine(cfg, params, decode_block=8)
    reqs = [Request(model="m", prompt=[2, 3],
                    sampling=SamplingParams(max_tokens=m))
            for m in (1, 3, 11)]
    _run(eng, reqs)
    assert [len(r.output) for r in reqs] == [1, 3, 11]
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_eos_stops_mid_block(cfg, params):
    eng = _engine(cfg, params, decode_block=8)
    probe = Request(model="m", prompt=[5, 6],
                    sampling=SamplingParams(max_tokens=10))
    _run(eng, [probe])
    eos = probe.output[3]                  # 4th greedy token as EOS
    r = Request(model="m", prompt=[5, 6],
                sampling=SamplingParams(max_tokens=10, eos_id=eos))
    _run(eng, [r])
    assert r.output == probe.output[:4]    # stopped exactly at EOS


# ------------------- bucketed prefill ------------------------------ #
def test_bucketed_prefill_compiles_once_per_bucket(cfg, params):
    """Every prompt length inside one power-of-two bucket shares a single
    trace; a new bucket costs exactly one more compile."""
    eng = _engine(cfg, params)
    for ln in (3, 4, 5, 6, 7, 8):
        _run(eng, [Request(model="m", prompt=list(range(ln)),
                           sampling=SamplingParams(max_tokens=2))])
    assert eng.prefill_traces == 1          # lengths 3..8 -> bucket 8
    _run(eng, [Request(model="m", prompt=list(range(9)),
                       sampling=SamplingParams(max_tokens=2))])
    assert eng.prefill_traces == 2          # length 9 -> bucket 16
    assert eng.decode_traces == 1           # decode compiled exactly once


def test_bucketed_prefill_matches_unpadded_outputs(cfg, params):
    """Padding to the bucket must not change any row's tokens: greedy
    outputs for different lengths equal the same prompts run alone (which
    also pad, but to a batch of one — cross-checks row independence)."""
    solo = [_run(_engine(cfg, params),
                 [Request(model="m", prompt=list(range(1, 2 + i)),
                          sampling=SamplingParams(max_tokens=5))])[0]
            for i in range(3)]
    batched = _run(_engine(cfg, params),
                   [Request(model="m", prompt=list(range(1, 2 + i)),
                            sampling=SamplingParams(max_tokens=5))
                    for i in range(3)])
    assert batched == solo


def test_scheduler_groups_same_bucket():
    sched = Scheduler(SchedulerConfig(max_prefill_per_step=3))
    lens = [3, 20, 5, 6, 18]               # buckets: 8, 32, 8, 8, 32
    reqs = [Request(model="m", prompt=list(range(n))) for n in lens]
    for r in reqs:
        sched.submit(r)

    def bucket_of(n):
        b = 8
        while b < n:
            b <<= 1
        return b
    group = sched.next_prefill_bucket(4, bucket_of)
    assert [len(r.prompt) for r in group] == [3, 5, 6]
    # skipped requests keep FCFS order for the next step
    group = sched.next_prefill_bucket(4, bucket_of)
    assert [len(r.prompt) for r in group] == [20, 18]
    assert sched.depth == 0


# ------------------- dispatch / sync discipline -------------------- #
def test_fused_block_cuts_dispatches_and_syncs(cfg, params):
    """The acceptance bar: K=8 issues >= 5x fewer device dispatches AND
    host syncs per generated token than K=1 on a decode-heavy workload.
    Deterministic counters — no timing flakiness."""
    stats = {}
    for k in (1, 8):
        eng = _engine(cfg, params, n_slots=4, decode_block=k)
        reqs = [Request(model="m", prompt=[1, 2, 3 + i],
                        sampling=SamplingParams(max_tokens=33))
                for i in range(6)]
        _run(eng, reqs)
        stats[k] = eng.perf_stats()
    assert stats[1]["tokens"] == stats[8]["tokens"]
    for metric in ("dispatches_per_token", "host_syncs_per_token"):
        assert stats[1][metric] / stats[8][metric] >= 5.0, (metric, stats)


# ------------------- cancel / release hygiene ---------------------- #
def test_cancel_clears_device_slot_state(cfg, params):
    """Cancelling an in-flight request zeroes its slot's persistent
    device arrays, so the freed slot can't be decoded or sampled with
    stale temperature/budget on the next fused dispatch."""
    eng = _engine(cfg, params, decode_block=4)
    a = Request(model="m", prompt=[1, 2],
                sampling=SamplingParams(max_tokens=1000, temperature=0.9,
                                        top_k=7))
    b = Request(model="m", prompt=[3, 4],
                sampling=SamplingParams(max_tokens=13))
    eng.submit(a), eng.submit(b)
    eng.step()
    slot_a = next(s for s, r in eng.slot_req.items() if r is a)
    assert eng.cancel(a.request_id)
    assert not bool(eng.active[slot_a])
    assert float(eng.temps[slot_a]) == 0.0
    assert int(eng.remaining[slot_a]) == 0
    eng.run_until_done()
    assert b.state == RequestState.FINISHED and len(b.output) == 13
    # the freed slot is reusable and produces a clean stream
    c = Request(model="m", prompt=[9], sampling=SamplingParams(max_tokens=4))
    _run(eng, [c])
    assert len(c.output) == 4


def test_decode_stops_at_cache_capacity(cfg, params):
    """A budget larger than the remaining cache stops cleanly at the
    cache edge (on-device capacity mask) instead of clamp-writing past
    max_len and emitting garbage forever."""
    eng = _engine(cfg, params, n_slots=2, max_len=16, decode_block=8)
    r = Request(model="m", prompt=list(range(1, 13)),   # 12 prompt tokens
                sampling=SamplingParams(max_tokens=100))
    _run(eng, [r])
    # first token + one decode per remaining cache slot (pos 12..15)
    assert len(r.output) == 16 - 12 + 1
    assert r.state == RequestState.FINISHED


def test_vision_prefix_prompt_near_max_len(param_store):
    """Prefix tokens count against the cache: a prompt that only fits
    without its vision prefix is rejected as invalid, and one that fits
    decodes fine even when bucket rounding would otherwise overflow."""
    vcfg = ARCHS["internvl2-76b"].reduced()
    eng = InferenceEngine(vcfg, param_store(vcfg),
                          EngineConfig(n_slots=2, max_len=24,
                                       decode_block=4))
    prefix = eng._prefix_tokens
    assert prefix > 0
    ok = Request(model="v", prompt=list(range(24 - prefix)),
                 sampling=SamplingParams(max_tokens=2))
    _run(eng, [ok])
    assert ok.state == RequestState.FINISHED and len(ok.output) >= 1
    bad = Request(model="v", prompt=list(range(24 - prefix + 1)),
                  sampling=SamplingParams(max_tokens=2))
    assert not eng.submit(bad)
    assert bad.error_code == CODE_INVALID_REQUEST


# ------------------- long-prompt classification -------------------- #
def test_long_prompt_is_invalid_at_submit(cfg, params):
    """A prompt no slot can ever hold is a 400, not a 429 — rejected at
    submit time, never enqueued."""
    eng = _engine(cfg, params)
    bad = Request(model="m", prompt=list(range(eng.ecfg.max_len + 1)),
                  sampling=SamplingParams(max_tokens=2))
    assert not eng.submit(bad)
    assert bad.state == RequestState.FAILED
    assert bad.error_code == CODE_INVALID_REQUEST
    assert eng.scheduler.depth == 0        # never reached the queue


def test_gateway_rejects_oversized_prompt_as_invalid(param_store):
    from repro.api import ErrorCode, Gateway
    from repro.cluster import BackendNode, Fleet
    from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                            SDAIController)
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=param_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    inst = fleet.nodes["n0"].deploy(cfg, n_slots=2, max_len=32)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, "", 2, 32, inst.bytes))
    gw = Gateway(ctrl)
    resp = gw.generate(cfg.name, list(range(33)),
                       SamplingParams(max_tokens=2))
    assert resp.error.code is ErrorCode.INVALID_REQUEST
    assert not resp.error.retryable
    assert inst.engine.scheduler.depth == 0    # rejected before routing
    assert gw.generate(cfg.name, [1, 2], SamplingParams(max_tokens=2)).ok


def test_gateway_counts_prefix_tokens_against_context(param_store):
    """Vision/meta prefix tokens occupy cache slots: a prompt that only
    fits without the prefix must be a 400 at the gateway (not a
    retryable NO_BACKEND after every replica refuses it)."""
    from repro.api import ErrorCode, Gateway
    from repro.cluster import BackendNode, Fleet
    from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                            SDAIController)
    cfg = ARCHS["internvl2-76b"].reduced()
    fleet = Fleet([BackendNode("n0", "v5e-1", param_store=param_store)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    inst = fleet.nodes["n0"].deploy(cfg, n_slots=2, max_len=24)
    ctrl.replicas.add(ReplicaInfo(ReplicaKey("n0", inst.instance_id),
                                  cfg.name, "", 2, 24, inst.bytes))
    gw = Gateway(ctrl)
    prefix = inst.engine._prefix_tokens
    assert prefix > 0
    resp = gw.generate(cfg.name, list(range(24 - prefix + 1)),
                       SamplingParams(max_tokens=2))
    assert resp.error.code is ErrorCode.INVALID_REQUEST
    assert not resp.error.retryable
    assert gw.generate(cfg.name, list(range(24 - prefix)),
                       SamplingParams(max_tokens=2)).ok


# ------------------- batched sampler parity ------------------------ #
def test_sample_batched_matches_single_params():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 64)) * 3.0
    for p in (SamplingParams(temperature=0.0),
              SamplingParams(temperature=0.8),
              SamplingParams(temperature=0.8, top_k=5),
              SamplingParams(temperature=0.8, top_k=5, top_p=0.7)):
        want = sample(logits, key, p)
        got = sample_batched(
            logits, key,
            jnp.full((4,), p.temperature, jnp.float32),
            jnp.full((4,), p.top_k, jnp.int32),
            jnp.full((4,), p.top_p, jnp.float32))
        assert want.tolist() == got.tolist(), p


def test_emit_many_preserves_streaming_contract():
    seen = []
    r = Request(model="m", prompt=[1],
                on_token=lambda req, t: seen.append(t))
    r.emit_many([7, 8, 9])
    assert seen == [7, 8, 9] and r.output == [7, 8, 9]
    assert r.first_token_at is not None

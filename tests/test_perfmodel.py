"""Heterogeneity-aware perf model, size-bucket routing, cost-optimal
placement, and class-aware elasticity (the Mélange-style cost story)."""
from repro.cluster import NODE_CLASSES, PAPER_TESTBED, BackendNode, Fleet
from repro.cluster.hardware import RUNTIME_RESERVE_FRACTION
from repro.configs import ZOO
from repro.core import ControllerConfig, ModelCatalog, ModelDemand, \
    SDAIController
from repro.core.frontend import FrontendConfig, ServiceFrontend
from repro.core.health import HealthConfig, HealthMonitor
from repro.core.perfmodel import (BUCKETS, DEFAULT_MIX, PerfModel,
                                  bucket_for, bucket_named, normalize_mix)
from repro.core.placement import (NodeSpec, as_vram_nodes, place,
                                  place_cost_optimal, plan_cost_per_token,
                                  plan_throughput)
from repro.core.registry import ReplicaInfo, ReplicaKey, ReplicaRegistry
from repro.serving.request import Request
from repro.serving.sampler import SamplingParams

GB = 1024 ** 3
MODEL = "llama3.2-1b"


# ------------------------------------------------------------------ #
# buckets + analytical model basics
# ------------------------------------------------------------------ #
def test_bucket_for_boundaries():
    assert bucket_for(8, 16).name == "short"
    assert bucket_for(128, 128).name == "short"
    assert bucket_for(129, 16).name == "medium"
    assert bucket_for(16, 300).name == "medium"
    assert bucket_for(600, 16).name == "long"
    assert bucket_for(8, 4096).name == "long"


def test_normalize_mix_sums_to_one_and_defaults():
    mix = normalize_mix({"short": 3.0, "long": 1.0})
    assert abs(sum(mix.values()) - 1.0) < 1e-9
    assert abs(mix["short"] - 0.75) < 1e-9
    default = normalize_mix(None)
    assert set(default) == {name for name, _ in DEFAULT_MIX}


def test_analytical_estimates_order_classes_sanely():
    """A faster-memory class decodes faster; legacy is cheaper per short
    token, big-VRAM cheaper per long token (the routing premise)."""
    pm = PerfModel()
    cfg = ZOO[MODEL]
    legacy, big = NODE_CLASSES["v2-legacy"], NODE_CLASSES["v5e-1"]
    short, long_ = bucket_named("short"), bucket_named("long")
    assert pm.tokens_per_s(big, cfg, "decode", short) > \
        pm.tokens_per_s(legacy, cfg, "decode", short)
    assert pm.cost_per_token(legacy, cfg, short) < \
        pm.cost_per_token(big, cfg, short)
    scores = pm.routing_scores([legacy, big], cfg, short)
    assert scores["v2-legacy"] == 1.0 and scores["v5e-1"] > 1.0
    scores = pm.routing_scores([legacy, big], cfg, long_)
    assert scores["v5e-1"] == 1.0 and scores["v2-legacy"] > 1.0


# ------------------------------------------------------------------ #
# calibration: measured rows override the analytical roofline
# ------------------------------------------------------------------ #
def test_calibration_overrides_analytical():
    pm = PerfModel()
    cfg = ZOO[MODEL]
    klass = NODE_CLASSES["v5e-1"]
    short = bucket_named("short")
    before = pm.estimate(klass, cfg, "decode", short)
    assert before.source == "analytical"
    # a bench measured 3x the analytical rate on this class
    pm.record(klass.name, cfg.name, "decode", short.name,
              before.tokens_per_s * 3.0)
    after = pm.estimate(klass, cfg, "decode", short)
    assert after.source == "measured"
    assert abs(after.tokens_per_s - before.tokens_per_s * 3.0) < 1e-6
    # measured throughput flows straight into cost-per-token
    assert pm.cost_per_token(klass, cfg, short) < \
        klass.cost_rate / before.tokens_per_s + 1e-12
    # other buckets / phases stay analytical
    assert pm.estimate(klass, cfg, "prefill", short).source == "analytical"
    assert pm.estimate(klass, cfg, "decode",
                       bucket_named("long")).source == "analytical"


def test_calibrate_from_bench_report_shape():
    pm = PerfModel()
    report = {"fused": {"b1": {"tok_per_s": 123.0},
                        "b4": {"tok_per_s": 456.0},
                        "junk": "not-a-row"}}
    n = pm.calibrate_from_bench(report, "v5e-1", MODEL)
    assert n == 2 * len(BUCKETS)
    assert pm.calibration_count() == len(BUCKETS)   # one row per bucket
    assert pm.measured("v5e-1", MODEL, "decode", "short") == 456.0


# ------------------------------------------------------------------ #
# size-bucket routing through the frontend
# ------------------------------------------------------------------ #
def _hetero_frontend():
    fleet = Fleet([BackendNode("leg0", "v2-legacy"),
                   BackendNode("leg1", "v2-legacy"),
                   BackendNode("big0", "v5e-1"),
                   BackendNode("big1", "v5e-1")])
    monitor = HealthMonitor(HealthConfig())
    replicas = ReplicaRegistry()
    cfg = ZOO[MODEL]
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, quantize="int8", n_slots=4, max_len=1024,
                           real=False)
        replicas.add(ReplicaInfo(ReplicaKey(node.node_id,
                                            inst.instance_id),
                                 MODEL, "int8", 4, 1024, inst.bytes))
        monitor.observe_heartbeat(node.node_id)
    fe = ServiceFrontend(fleet, replicas, monitor, FrontendConfig())
    return fleet, fe


def test_short_routes_to_legacy_long_to_big_vram():
    """Under concurrent mixed traffic, short chats land on the cheap
    legacy class and long-context requests on the big-VRAM class."""
    fleet, fe = _hetero_frontend()
    for _ in range(12):
        assert fe.submit(Request(model=MODEL, prompt=[1] * 8,
                                 sampling=SamplingParams(max_tokens=4)))
        assert fe.submit(Request(model=MODEL, prompt=[1] * 600,
                                 sampling=SamplingParams(max_tokens=4)))
    short = fe.stats.per_bucket_class["short"]
    long_ = fe.stats.per_bucket_class["long"]
    assert short.get("v2-legacy", 0) == 12 and "v5e-1" not in short
    assert long_.get("v5e-1", 0) == 12 and "v2-legacy" not in long_
    assert fe.stats.routed_by_bucket == {"short": 12, "long": 12}


def test_bucket_routing_is_preference_not_partition():
    """If every big-VRAM replica dies, long requests still get served —
    the affinity is a virtual-load nudge, not a hard partition."""
    fleet, fe = _hetero_frontend()
    fleet.fail_node("big0")
    fleet.fail_node("big1")
    req = Request(model=MODEL, prompt=[1] * 600,
                  sampling=SamplingParams(max_tokens=4))
    assert fe.submit(req)
    assert req.node in ("leg0", "leg1")


# ------------------------------------------------------------------ #
# cost-optimal placement vs the class-blind VRAM packer
# ------------------------------------------------------------------ #
def _testbed_specs():
    out = {}
    for i, (nid, kname) in enumerate(PAPER_TESTBED):
        klass = NODE_CLASSES[kname]
        free = int(klass.hbm_total * (1 - RUNTIME_RESERVE_FRACTION))
        out[nid] = NodeSpec(free, klass)
    return out


def test_cost_optimal_beats_vram_packer_at_equal_demand():
    nodes = _testbed_specs()
    demands = [
        ModelDemand(ZOO[MODEL], min_replicas=2, max_len=2048,
                    bucket_mix=(("short", 0.7), ("medium", 0.3))),
        ModelDemand(ZOO["deepseek-r1-7b"], min_replicas=1, max_len=4096,
                    bucket_mix=(("long", 1.0),)),
    ]
    perf = PerfModel()
    vram = place(as_vram_nodes(nodes), demands, fill=False)
    cost = place_cost_optimal(nodes, demands, perf, fill=False)
    # equal placed demand: same replica counts, nothing dropped
    assert not vram.unplaced and not cost.unplaced
    assert len(vram.assignments) == len(cost.assignments)
    # VRAM budgets respected
    used = {}
    for a in cost.assignments:
        used[a.node_id] = used.get(a.node_id, 0) + a.bytes
    for nid, total in used.items():
        assert total <= nodes[nid].free
    # and the cost-aware mix is strictly cheaper per modeled token
    cpt_vram = plan_cost_per_token(vram, nodes, demands, perf)
    cpt_cost = plan_cost_per_token(cost, nodes, demands, perf)
    assert cpt_cost < cpt_vram


def test_slo_top_up_adds_replicas_until_target_met():
    nodes = _testbed_specs()
    perf = PerfModel()
    base = ModelDemand(ZOO[MODEL], min_replicas=1, max_replicas=4,
                       bucket_mix=(("short", 1.0),))
    lone = place_cost_optimal(nodes, [base], perf, fill=False)
    one_rep = plan_throughput(lone, nodes, [base], perf)[MODEL]
    hungry = ModelDemand(ZOO[MODEL], min_replicas=1, max_replicas=4,
                         bucket_mix=(("short", 1.0),),
                         target_tokens_per_s=one_rep * 2.5)
    plan = place_cost_optimal(nodes, [hungry], perf, fill=False)
    assert len(plan.assignments) >= 3
    assert plan_throughput(plan, nodes, [hungry], perf)[MODEL] >= \
        one_rep * 2.5


# ------------------------------------------------------------------ #
# class-aware elasticity in the controller
# ------------------------------------------------------------------ #
def _hetero_controller():
    # v5lite-1 runs this model at ~2x the modeled cost-per-token of
    # v2-legacy (3.5x the price for <2x the speed), so cost strictly
    # orders the classes
    fleet = Fleet([BackendNode("leg0", "v2-legacy"),
                   BackendNode("leg1", "v2-legacy"),
                   BackendNode("exp0", "v5lite-1")])
    catalog = ModelCatalog()
    catalog.register(ZOO[MODEL])
    ctrl = SDAIController(fleet, catalog,
                          ControllerConfig(fill_vram=False))
    ctrl.discover()
    return fleet, ctrl


def _one_per_node_demand():
    # bf16-only and sized so a 6GB legacy node fits exactly one replica:
    # the class choice is the only degree of freedom left
    return ModelDemand(ZOO[MODEL], min_replicas=1, max_replicas=3,
                       n_slots=8, max_len=2048, allow_quant=False,
                       bucket_mix=(("short", 1.0),))


def test_scale_up_picks_cheapest_satisfying_class():
    fleet, ctrl = _hetero_controller()
    plan = ctrl.deploy([_one_per_node_demand()])
    assert not plan.unplaced
    first = {a.node_id for a in plan.assignments}
    assert first <= {"leg0", "leg1"}       # short mix: legacy cheapest
    assert ctrl.scale_up(MODEL)
    hosts = {info.key.node_id
             for info in ctrl.replicas.for_model(MODEL)}
    # the delta replica also lands on the cheaper class while a node
    # of it still has room, not on the pricier v5lite-1 node
    assert hosts == {"leg0", "leg1"}


def test_scale_down_retires_most_expensive_class_first():
    fleet, ctrl = _hetero_controller()
    ctrl.deploy([_one_per_node_demand()])
    for _ in range(2):
        assert ctrl.scale_up(MODEL)
    hosts = {info.key.node_id
             for info in ctrl.replicas.for_model(MODEL)}
    assert hosts == {"leg0", "leg1", "exp0"}    # cheap full -> pricey
    assert ctrl.scale_down(MODEL)
    hosts = {info.key.node_id
             for info in ctrl.replicas.for_model(MODEL)}
    assert hosts == {"leg0", "leg1"}    # most expensive retired first

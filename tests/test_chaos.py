"""Survivable streams: mid-stream migration keeps every emitted token,
bills exactly once, and the seeded chaos harness makes the whole story
reproducible — kill schedules fire at exact steps, and a faulted run's
greedy output is token-identical to the fault-free run."""
import time

from repro.api import (ErrorCode, Gateway, RuntimeConfig,
                       StreamEventType)
from repro.cluster import BackendNode, FaultInjector, FaultSpec, Fleet
from repro.configs import ARCHS
from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                        SDAIController)
from repro.core.events import (FAULT_INJECTED, NODE_SUSPECTED,
                               REQUEST_MIGRATED, WATCHDOG_FIRED)
from repro.core.health import NodeHealth
from repro.serving import SamplingParams

MODEL = "olmo-1b-reduced"


def _pinned_stack(param_store, n_nodes=2, n_slots=2, max_len=48):
    """One REAL engine per node, registered manually so replicas are
    guaranteed to span nodes (migration needs a cross-node survivor)."""
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=n_slots, max_len=max_len)
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", n_slots, max_len, inst.bytes))
    return fleet, ctrl


def _stream_tokens(handle, timeout_s=120):
    toks = []
    for ev in handle.stream(timeout_s=timeout_s):
        if ev.type is StreamEventType.TOKEN:
            toks.append((ev.index, ev.token))
    return toks


# ---------------- mid-stream migration (hand-pump) ------------------ #
def test_midstream_migration_is_token_identical(param_store):
    """Kill the serving node after tokens have streamed: the stream
    resumes on the survivor and the final output is exactly what the
    fault-free run produced — no loss, no duplication, no reorder."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    prompt, n = [3, 1, 4, 1, 5], 8
    reference = gw.generate(MODEL, prompt, SamplingParams(max_tokens=n))
    assert reference.ok and len(reference.tokens) == n

    handle = gw.submit(MODEL, prompt, SamplingParams(max_tokens=n),
                       tenant="steady")
    it = handle.stream()
    streamed = []
    for _ in range(2):                      # some tokens out the door
        ev = next(it)
        assert ev.type is StreamEventType.TOKEN
        streamed.append((ev.index, ev.token))
    victim = handle.internal.node
    fleet.fail_node(victim)                 # crash mid-decode
    for ev in it:
        if ev.type is StreamEventType.TOKEN:
            streamed.append((ev.index, ev.token))
    resp = handle.response

    assert resp.ok, resp.error
    assert resp.node != victim              # served out by the survivor
    assert resp.retries >= 1
    assert list(resp.tokens) == list(reference.tokens)
    # the SSE journal: contiguous indices, tokens == final response
    assert [i for i, _ in streamed] == list(range(n))
    assert [t for _, t in streamed] == list(resp.tokens)
    assert gw.stats.migrations >= 1
    migrated = ctrl.bus.of_kind(REQUEST_MIGRATED)
    assert migrated and migrated[-1].data["from_node"] == victim
    # the journal is authoritative: at least the 2 consumed tokens were
    # resumed (the engine may have banked more from its decode block)
    assert 2 <= migrated[-1].data["tokens_resumed"] < n


def test_migration_bills_wfq_and_tenant_exactly_once(param_store):
    """Across a migration the request pays for max_tokens once: the WFQ
    virtual clock advances by the full budget exactly once (journal
    floor on the new replica) and the tenant bucket was charged only at
    admission."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    gw.admin.set_tenant_quota("acct", tokens_per_s=10_000)
    n = 6
    handle = gw.submit(MODEL, [2, 7], SamplingParams(max_tokens=n),
                       tenant="acct")
    it = handle.stream()
    assert next(it).type is StreamEventType.TOKEN
    fleet.fail_node(handle.internal.node)
    list(it)
    resp = handle.response
    assert resp.ok and len(resp.tokens) == n
    # exactly-once WFQ billing: served-journal floor means the victim's
    # charge plus the survivor's tops out at the request budget
    assert handle.internal.wfq_charged == float(n)
    usage = ctrl.frontend.tenants.snapshot()["acct"]["usage"]
    assert usage.tokens_charged == n        # admission-time, once


def test_single_node_failure_still_surfaces_error(param_store):
    """No survivor => no migration: the structured mid-stream failure
    contract from PR 4 is unchanged."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=1)
    gw = Gateway(ctrl)
    handle = gw.submit(MODEL, [9, 9], SamplingParams(max_tokens=10_000))
    it = handle.stream()
    assert next(it).type is StreamEventType.TOKEN
    fleet.fail_node(handle.internal.node)
    events = list(it)
    assert events[-1].type is StreamEventType.ERROR
    assert events[-1].error.code is ErrorCode.ENGINE_FAILED
    assert gw.stats.migrations == 0


# ---------------- zombie fencing ------------------------------------ #
def test_silent_heartbeat_loss_fences_zombie_and_migrates(param_store):
    """A node that stops heartbeating but keeps running (chaos
    `mute_heartbeat`) is fenced by the controller — fail()ed, not just
    unrouted — and its in-flight stream migrates to the survivor."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    ctrl.monitor.cfg.suspect_after = 0.02
    ctrl.monitor.cfg.dead_after = 0.05
    gw = Gateway(ctrl)
    ctrl.tick()                             # fresh heartbeats all around

    handle = gw.submit(MODEL, [1, 6, 1], SamplingParams(max_tokens=6))
    it = handle.stream()
    assert next(it).type is StreamEventType.TOKEN
    victim = handle.internal.node
    inj = FaultInjector([FaultSpec("mute_heartbeat", victim, at_step=1)],
                        bus=ctrl.bus).install(fleet)
    inj.on_step(fleet.nodes[victim])        # window opens
    assert fleet.nodes[victim].heartbeat() is None
    assert fleet.nodes[victim].alive        # the zombie is still up
    time.sleep(0.08)                        # victim misses its deadline
    ctrl.tick()
    assert not fleet.nodes[victim].alive    # fenced, not split-brained
    toks = [(ev.index, ev.token) for ev in it
            if ev.type is StreamEventType.TOKEN]
    resp = handle.response
    assert resp.ok, resp.error
    assert resp.node != victim
    first = [(i, t) for i, t in enumerate(resp.tokens)][:1]
    assert first + toks == list(enumerate(resp.tokens))
    assert ctrl.bus.of_kind(FAULT_INJECTED)
    inj.uninstall()


# ---------------- chaos soak (runtime, seeded) ---------------------- #
def test_seeded_chaos_soak_streams_survive_node_kill(param_store):
    """N tenants stream through the live runtime while a seeded kill
    schedule takes out a node mid-decode.  Every stream completes, every
    greedy output is token-identical to the fault-free run, and no
    survivor leaks a single KV page."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=3, n_slots=2)
    gw = Gateway(ctrl)
    prompts = [[1, 2, i + 1] for i in range(6)]
    n = 24          # long enough that the kill lands mid-decode
    # fault-free reference pass (greedy => per-prompt deterministic)
    reference = {}
    for p in prompts:
        r = gw.generate(MODEL, p, SamplingParams(max_tokens=n),
                        timeout_s=120)
        assert r.ok
        reference[tuple(p)] = list(r.tokens)

    inj = FaultInjector.kill_schedule(
        seed=1234, node_ids=list(fleet.nodes), n_kills=1,
        first_step=3).install(fleet, bus=ctrl.bus)
    gw.start(RuntimeConfig(tick_interval_s=0.02))
    try:
        tenants = ["alpha", "beta", "gamma"]
        handles = [(p, gw.submit(MODEL, p, SamplingParams(max_tokens=n),
                                 tenant=tenants[i % len(tenants)]))
                   for i, p in enumerate(prompts)]
        results = [(p, h, _stream_tokens(h)) for p, h in handles]
    finally:
        assert gw.stop(timeout_s=60) is True
        inj.uninstall()

    assert inj.fired, "the kill schedule never fired"
    dead = {s.node for _, s in inj.fired if s.kind == "crash"}
    assert dead and all(not fleet.nodes[d].alive for d in dead)
    for p, h, toks in results:
        resp = h.response
        assert resp.ok, (p, resp.error)
        # tokens_lost == 0 and tokens_duplicated == 0, by construction:
        # the stream journal equals the fault-free greedy reference
        assert [i for i, _ in toks] == list(range(n))
        assert [t for _, t in toks] == reference[tuple(p)]
        assert list(resp.tokens) == reference[tuple(p)]
    # streams that were in flight on the victim really migrated
    assert gw.stats.migrations + gw.stats.stream_retries >= 1
    # no leaked pages on any surviving engine
    for node in fleet.nodes.values():
        if not node.alive:
            continue
        for inst in node.instances.values():
            if inst.engine is not None:
                assert inst.engine.pool.pages_in_use == 0
                assert inst.engine.pool.n_active == 0
    # the failure surface is observable end to end
    snap = gw.admin.snapshot()
    assert snap.failure_events.get(FAULT_INJECTED, 0) >= 1
    assert snap.failure_events == snap.to_dict()["failures"]


def test_chaos_schedule_is_deterministic(param_store):
    """Same seed, same fleet, same workload => identical fault firings
    and identical tokens, run to run."""
    outs = []
    for _ in range(2):
        fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
        gw = Gateway(ctrl)
        inj = FaultInjector.kill_schedule(
            seed=77, node_ids=list(fleet.nodes), n_kills=1,
            first_step=5).install(fleet)
        h = gw.submit(MODEL, [4, 2], SamplingParams(max_tokens=6))
        toks = _stream_tokens(h)
        assert h.response.ok
        outs.append(([(step, s.kind, s.node) for step, s in inj.fired],
                     toks))
        inj.uninstall()
    assert outs[0] == outs[1]


# ---------------- watchdog + straggler ------------------------------ #
def test_watchdog_demotes_hung_pump_then_clears(param_store):
    """A chaos `hang` stalls one node's pump past the watchdog deadline:
    the node goes SUSPECT (demoted in routing, event emitted) and the
    mark clears once the stall window passes."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    victim = next(iter(fleet.nodes))
    inj = FaultInjector(
        [FaultSpec("hang", victim, at_step=1, duration_steps=3,
                   stall_s=0.25)], bus=ctrl.bus).install(fleet)
    rt = gw.start(RuntimeConfig(tick_interval_s=0.01,
                                watchdog_step_timeout_s=0.05))
    try:
        h = gw.submit(MODEL, [5, 5], SamplingParams(max_tokens=6))
        deadline = time.monotonic() + 30
        while rt.stats.watchdog_fired == 0 \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        assert rt.stats.watchdog_fired >= 1
        assert ctrl.bus.of_kind(WATCHDOG_FIRED)
        assert ctrl.bus.of_kind(NODE_SUSPECTED)
        assert h.result(timeout_s=120).ok
        # the stall window passed: the suspect mark clears
        deadline = time.monotonic() + 30
        while victim in ctrl.monitor.suspect_marks \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        assert victim not in ctrl.monitor.suspect_marks
        assert ctrl.monitor.status(victim) is NodeHealth.HEALTHY
    finally:
        assert gw.stop(timeout_s=60) is True
        inj.uninstall()


def test_suspect_mark_demotes_routing(param_store):
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    ctrl.tick()
    nid = next(iter(fleet.nodes))
    ctrl.monitor.mark_suspect(nid)
    assert ctrl.monitor.status(nid) is NodeHealth.SUSPECT
    # suspect replicas stay routable (availability > strictness) but a
    # healthy peer wins the weighted pick
    gw = Gateway(ctrl)
    h = gw.submit(MODEL, [8, 8], SamplingParams(max_tokens=2))
    assert h.internal.node != nid
    assert h.result(timeout_s=120).ok
    ctrl.monitor.clear_suspect(nid)
    ctrl.tick()         # fresh heartbeats: no age-based demotion left
    assert ctrl.monitor.status(nid) is NodeHealth.HEALTHY


# ---------------- submit flap + swap failure ------------------------ #
def test_submit_flap_fails_over_to_peer(param_store):
    """A flapping node refuses submits for a window: the frontend's
    retry loop lands the request on the peer; nothing is lost."""
    fleet, ctrl = _pinned_stack(param_store, n_nodes=2)
    gw = Gateway(ctrl)
    flappy = next(iter(fleet.nodes))
    inj = FaultInjector([FaultSpec("flap", flappy, at_step=1)],
                        bus=ctrl.bus).install(fleet)
    inj.on_step(fleet.nodes[flappy])        # open the window
    assert inj.submit_blocked(flappy)
    for i in range(4):
        h = gw.submit(MODEL, [6, i + 1], SamplingParams(max_tokens=3))
        resp = h.result(timeout_s=120)
        assert resp.ok, resp.error
        assert resp.node != flappy
    inj.uninstall()
    assert not inj.submit_blocked(flappy)


def test_swap_fail_window_forces_recompute_fallback(param_store):
    """With the host swap tier refusing puts (chaos `swap_fail`), the
    engine's preemption path must fall back to recompute — requests
    still finish, and the host pool stays clean."""
    from repro.serving.kv_hierarchy import HostPagePool
    pool = HostPagePool(4)
    assert pool.can_hold(2)
    pool.fail_puts = True
    assert not pool.can_hold(1)
    assert pool.put([], 0) is None          # refused outright
    pool.fail_puts = False
    assert pool.can_hold(2)

    fleet, ctrl = _pinned_stack(param_store, n_nodes=1)
    nid = next(iter(fleet.nodes))
    inj = FaultInjector([FaultSpec("swap_fail", nid, at_step=1,
                                   duration_steps=2)]).install(fleet)
    node = fleet.nodes[nid]
    inj.on_step(node)                       # window opens
    for inst in node.instances.values():
        if inst.engine is not None and inst.engine.host_pool is not None:
            assert inst.engine.host_pool.fail_puts
    inj.on_step(node)
    inj.on_step(node)                       # window expires
    for inst in node.instances.values():
        if inst.engine is not None and inst.engine.host_pool is not None:
            assert not inst.engine.host_pool.fail_puts
    inj.uninstall()

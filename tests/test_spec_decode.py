"""On-device n-gram speculative decoding: proposer-table unit behavior,
provable greedy parity (spec on/off token-identical), real acceptance on
repetition-heavy workloads, graceful no-proposal fallback, cancel/preempt
hygiene (no stale drafts leak into a reused slot), and mid-speculation
migration under the chaos harness with exactly-once WFQ billing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Gateway, StreamEventType
from repro.configs import ARCHS
from repro.core.events import REQUEST_MIGRATED
from repro.serving import (EngineConfig, InferenceEngine, Request,
                           SamplingParams)
from repro.serving import spec_decode as sd


@pytest.fixture(scope="module")
def cfg():
    return ARCHS["olmo-1b"].reduced()


@pytest.fixture(scope="module")
def params(cfg, param_store):
    return param_store(cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("paged_attention", True)
    kw.setdefault("speculative", True)
    return InferenceEngine(cfg, params, EngineConfig(**kw))


def _run(eng, reqs):
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_done()
    return [tuple(r.output) for r in reqs]


def _work(n=5, max_tokens=12):
    return [Request(model="m", prompt=list(range(1, 2 + i)),
                    sampling=SamplingParams(max_tokens=max_tokens + i))
            for i in range(n)]


# ------------------- proposer-table units -------------------------- #
def test_propose_empty_table_yields_no_proposal():
    table, prev = sd.init_tables(3, 64)
    drafts = sd.propose(table, prev, jnp.asarray([5, 6, 7], jnp.int32), 4)
    assert (np.asarray(drafts) == -1).all()


def test_record_then_propose_chains_bigrams():
    """Teach one slot a -> b -> c -> d chain; propose from (a, b) must
    return [c, d, -1, ...] while an untaught slot still proposes
    nothing."""
    table, _ = sd.init_tables(2, 64)
    rows = jnp.asarray([0, 0], jnp.int32)
    a, b, c, d = 11, 12, 13, 14
    valid = jnp.asarray([True, False])
    table = sd.record(table, jnp.asarray([a, 0]), jnp.asarray([b, 0]),
                      jnp.asarray([c, 0]), valid)
    table = sd.record(table, jnp.asarray([b, 0]), jnp.asarray([c, 0]),
                      jnp.asarray([d, 0]), valid)
    drafts = sd.propose(table, jnp.asarray([a, a], jnp.int32),
                        jnp.asarray([b, b], jnp.int32), 3)
    assert np.asarray(drafts)[0].tolist() == [c, d, -1]
    assert (np.asarray(drafts)[1] == -1).all()   # row 1 never learned
    del rows


def test_record_invalid_rows_never_dirty_table():
    table, _ = sd.init_tables(1, 64)
    t2 = sd.record(table, jnp.asarray([3]), jnp.asarray([4]),
                   jnp.asarray([5]), jnp.asarray([False]))
    assert (np.asarray(t2) == -1).all()
    # negative tokens (unknown chain seed) are also dropped
    t3 = sd.record(table, jnp.asarray([-1]), jnp.asarray([4]),
                   jnp.asarray([5]), jnp.asarray([True]))
    assert (np.asarray(t3) == -1).all()


def test_accept_length_longest_matching_prefix():
    drafts = jnp.asarray([[1, 2, 3], [1, 9, 3], [9, 2, 3], [-1, -1, -1]])
    greedy = jnp.asarray([[1, 2, 3], [1, 2, 3], [1, 2, 3], [1, 2, 3]])
    assert np.asarray(
        sd.accept_length(drafts, greedy)).tolist() == [3, 1, 0, 0]


# ------------------- greedy parity --------------------------------- #
@pytest.mark.parametrize("d", [2, 4])
def test_spec_greedy_parity(cfg, params, d):
    """Greedy verify makes speculation provably lossless: outputs are
    token-identical with speculation on and off, for any draft depth."""
    ref = _run(_engine(cfg, params, speculative=False, decode_block=1),
               _work())
    eng = _engine(cfg, params, spec_draft=d, decode_block=1)
    assert _run(eng, _work()) == ref
    st = eng.perf_stats()
    assert st["speculative"] and st["spec_dispatches"] > 0


def test_acceptance_above_one_on_repetitive_workload(cfg, params):
    """The tiny random-weight model emits long repeated runs, the
    bigram table learns them, and each verify dispatch must then emit
    more than one token on average — the speedup the proposer exists
    for — with per-slot acceptance counters accounting for every extra
    token."""
    eng = _engine(cfg, params, spec_draft=4, decode_block=1)
    _run(eng, _work(max_tokens=20))
    st = eng.perf_stats()
    assert st["spec_accepted_per_dispatch"] > 1.0, st
    # every accepted draft is one emitted token beyond a slot's base
    # token; each dispatch hands at least one base token to some slot
    accepted = int(np.asarray(st["spec_slot_accepted"]).sum())
    assert 0 < accepted <= st["spec_emitted"] - st["spec_dispatches"]


def test_no_proposal_fallback_costs_one_dispatch_per_token(cfg, params):
    """With an empty proposer table every draft is -1, acceptance is 0,
    and each verify emits exactly its own argmax — never worse than the
    K=1 fused baseline in dispatches per token."""
    eng = _engine(cfg, params, spec_draft=4, decode_block=1)
    r = Request(model="m", prompt=[1, 2, 3],
                sampling=SamplingParams(max_tokens=3))
    _run(eng, [r])
    st = eng.perf_stats()
    # 3 decode tokens after the admission token: <= 1 verify dispatch
    # each (acceptance == 0 never costs an *extra* dispatch)
    assert st["spec_dispatches"] <= 3
    assert st["spec_emitted"] + 1 == st["tokens"]


def test_sampled_batches_fall_back_to_fused(cfg, params):
    """Speculation is greedy-only: a batch containing a temperature>0
    request routes through the fused path (correctness first), with
    zero verify dispatches."""
    eng = _engine(cfg, params, decode_block=2)
    reqs = [Request(model="m", prompt=[1, 2],
                    sampling=SamplingParams(max_tokens=6)),
            Request(model="m", prompt=[3, 4],
                    sampling=SamplingParams(max_tokens=6,
                                            temperature=0.8))]
    _run(eng, reqs)
    assert eng.perf_stats()["spec_dispatches"] == 0
    assert all(len(r.output) == 6 for r in reqs)


# ------------------- cancel / hygiene ------------------------------ #
def test_cancel_wipes_proposer_state(cfg, params):
    """Cancelling a speculating request must clear its slot's proposer
    row and chain seed on device — un-verified drafts from the dead
    request can never be proposed into a reused slot."""
    eng = _engine(cfg, params, n_slots=2, spec_draft=4, decode_block=1)
    victim = Request(model="m", prompt=[1, 2, 3],
                     sampling=SamplingParams(max_tokens=40))
    assert eng.submit(victim)
    for _ in range(4):                     # let the table learn a chain
        eng.step()
    slot = next(iter(eng.slot_req))
    assert (np.asarray(eng.spec_table)[slot] >= 0).any(), \
        "victim never populated its proposer row"
    assert eng.cancel(victim.request_id) == "active"
    assert (np.asarray(eng.spec_table)[slot] == -1).all()
    assert np.asarray(eng.spec_prev)[slot] == -1


def test_reused_slot_sees_no_stale_drafts(cfg, params):
    """A request admitted into a just-cancelled slot decodes exactly as
    it would on a fresh engine — byte-for-byte, so no stale draft or
    chain seed leaked through slot reuse."""
    probe = Request(model="m", prompt=[4, 5],
                    sampling=SamplingParams(max_tokens=10))
    ref = _run(_engine(cfg, params, n_slots=1, spec_draft=4,
                       decode_block=1), [probe])
    eng = _engine(cfg, params, n_slots=1, spec_draft=4, decode_block=1)
    victim = Request(model="m", prompt=[1, 2, 3],
                     sampling=SamplingParams(max_tokens=40))
    assert eng.submit(victim)
    for _ in range(4):
        eng.step()
    assert eng.cancel(victim.request_id) == "active"
    fresh = Request(model="m", prompt=[4, 5],
                    sampling=SamplingParams(max_tokens=10))
    assert _run(eng, [fresh]) == ref


# ------------------- chaos: mid-speculation migration -------------- #
def test_midspeculation_migration_token_identical_and_billed_once(
        param_store):
    """Kill the node serving a speculating stream after tokens are out:
    the stream resumes on the survivor (which re-seeds its own proposer
    from the journal) with the exact fault-free output and exactly-once
    WFQ billing."""
    from repro.cluster import BackendNode, Fleet
    from repro.core import (ModelCatalog, ReplicaInfo, ReplicaKey,
                            SDAIController)
    cfg = ARCHS["olmo-1b"].reduced()
    fleet = Fleet([BackendNode(f"n{i}", "v5e-1", param_store=param_store)
                   for i in range(2)])
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.discover()
    for node in fleet.nodes.values():
        inst = node.deploy(cfg, n_slots=2, max_len=48,
                           paged_attention=True, speculative=True)
        assert inst.engine._spec_ok
        ctrl.replicas.add(ReplicaInfo(
            ReplicaKey(node.node_id, inst.instance_id),
            cfg.name, "", 2, 48, inst.bytes))
    gw = Gateway(ctrl)
    gw.admin.set_tenant_quota("acct", tokens_per_s=10_000)
    prompt, n = [3, 1, 4, 1, 5], 8
    reference = gw.generate(cfg.name, prompt, SamplingParams(max_tokens=n))
    assert reference.ok and len(reference.tokens) == n

    handle = gw.submit(cfg.name, prompt, SamplingParams(max_tokens=n),
                       tenant="acct")
    it = handle.stream()
    streamed = []
    for _ in range(2):                     # some tokens out the door
        ev = next(it)
        assert ev.type is StreamEventType.TOKEN
        streamed.append((ev.index, ev.token))
    victim = handle.internal.node
    fleet.fail_node(victim)                # crash mid-speculation
    for ev in it:
        if ev.type is StreamEventType.TOKEN:
            streamed.append((ev.index, ev.token))
    resp = handle.response
    assert resp.ok, resp.error
    assert resp.node != victim
    assert list(resp.tokens) == list(reference.tokens)
    assert [i for i, _ in streamed] == list(range(n))
    assert [t for _, t in streamed] == list(resp.tokens)
    migrated = ctrl.bus.of_kind(REQUEST_MIGRATED)
    assert migrated and migrated[-1].data["from_node"] == victim
    # exactly-once WFQ billing across the migration
    assert handle.internal.wfq_charged == float(n)
    usage = ctrl.frontend.tenants.snapshot()["acct"]["usage"]
    assert usage.tokens_charged == n

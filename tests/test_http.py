"""Wire protocol v1: ErrorCode -> HTTP status mapping, SSE framing,
chat-template golden renders, tenant auth + rate limiting over keep-alive
connections, remote cancel, drain-on-stop, and HTTP-vs-in-process greedy
parity."""
import http.client
import json
import threading
import time

import pytest

from repro.api import ErrorCode, Gateway, GatewayConfig
from repro.api.http import (HTTP_STATUS, ChatMessage, GatewayHTTPServer,
                            HTTPClient, HTTPClientError, HTTPConfig,
                            decode_tokens, encode_text, error_body,
                            render_prompt, template_for)
from repro.api.http.chat import CHATML, GEMMA, LLAMA3, PLAIN
from repro.api.types import APIError
from repro.cluster import BackendNode, Fleet
from repro.configs import ARCHS, ZOO
from repro.core import ModelCatalog, ModelDemand, SDAIController
from repro.serving import SamplingParams

MODEL = "olmo-1b-reduced"


def _stack(param_store, n_nodes=2, n_slots=2, max_len=160,
           min_replicas=2):
    fleet = Fleet([BackendNode(f"h{i}", "v5e-1", param_store=param_store)
                   for i in range(n_nodes)])
    cfg = ARCHS["olmo-1b"].reduced()
    catalog = ModelCatalog()
    catalog.register(cfg)
    ctrl = SDAIController(fleet, catalog)
    ctrl.cfg.fill_vram = False
    ctrl.discover()
    plan = ctrl.deploy([ModelDemand(cfg, min_replicas=min_replicas,
                                    max_replicas=min_replicas,
                                    n_slots=n_slots, max_len=max_len)])
    assert not plan.unplaced
    return fleet, ctrl


@pytest.fixture(scope="module")
def server(param_store):
    """Module-shared healthy service (tests that kill nodes or need a
    special GatewayConfig build their own)."""
    _, ctrl = _stack(param_store)
    srv = GatewayHTTPServer(Gateway(ctrl), HTTPConfig(port=0)).start()
    yield srv
    assert srv.stop(timeout_s=30.0)


@pytest.fixture()
def client(server):
    c = HTTPClient(server.url())
    yield c
    c.close()


# -------------------- error mapping -------------------------------- #
def test_status_table_covers_every_error_code():
    expected = {
        ErrorCode.NO_BACKEND: 503, ErrorCode.OVERLOADED: 429,
        ErrorCode.ENGINE_FAILED: 500, ErrorCode.CANCELLED: 499,
        ErrorCode.TIMEOUT: 504, ErrorCode.DRAINING: 503,
        ErrorCode.INVALID_REQUEST: 400, ErrorCode.RATE_LIMITED: 429,
    }
    assert HTTP_STATUS == expected          # every code, documented status
    for code in ErrorCode:
        body = error_body(APIError(code, "boom"))["error"]
        assert body["type"] == code.value
        assert body["code"] == expected[code]
        assert body["message"] == "boom"
        assert body["retryable"] == code.retryable


def test_every_error_code_reachable_over_http(param_store):
    """One stack, every taxonomy entry observed from the wire with its
    documented status (CANCELLED/ENGINE_FAILED via their own scenarios
    below)."""
    _, ctrl = _stack(param_store)
    srv = GatewayHTTPServer(Gateway(ctrl), HTTPConfig(port=0)).start()
    c = HTTPClient(srv.url())
    try:
        # INVALID_REQUEST (400): empty prompt
        with pytest.raises(HTTPClientError) as e:
            c.complete(MODEL, [], max_tokens=2)
        assert (e.value.status, e.value.code) == (
            400, ErrorCode.INVALID_REQUEST)
        # ... also malformed JSON bodies
        conn = http.client.HTTPConnection("127.0.0.1", srv.port)
        conn.request("POST", "/v1/completions", b"{not json",
                     {"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
        conn.close()
        # NO_BACKEND (503): nothing serves the model
        with pytest.raises(HTTPClientError) as e:
            c.complete("ghost-model", [1], max_tokens=2)
        assert (e.value.status, e.value.code) == (
            503, ErrorCode.NO_BACKEND)
        assert e.value.retryable
        # TIMEOUT (504): sub-millisecond wall-clock deadline
        with pytest.raises(HTTPClientError) as e:
            c.complete(MODEL, [1, 2], max_tokens=140,
                       timeout_s=0.001)
        assert (e.value.status, e.value.code) == (504, ErrorCode.TIMEOUT)
        # RATE_LIMITED (429): tenant bucket of one request, no refill
        c.set_tenant_quota("wire-capped", requests_per_s=0.001,
                           burst_requests=1)
        capped = HTTPClient(srv.url(), tenant="wire-capped")
        assert capped.complete(MODEL, [1], max_tokens=2)["choices"]
        with pytest.raises(HTTPClientError) as e:
            capped.complete(MODEL, [1], max_tokens=2)
        assert (e.value.status, e.value.code) == (
            429, ErrorCode.RATE_LIMITED)
        capped.close()
        # DRAINING (503): admin drain, then resume restores service
        assert c.admin_drain(MODEL)["drained"]
        with pytest.raises(HTTPClientError) as e:
            c.complete(MODEL, [1], max_tokens=2)
        assert (e.value.status, e.value.code) == (503, ErrorCode.DRAINING)
        c.admin_resume(MODEL)
        assert c.complete(MODEL, [1], max_tokens=2)["choices"]
    finally:
        c.close()
        assert srv.stop(timeout_s=30.0)


def test_overloaded_maps_to_429(param_store):
    _, ctrl = _stack(param_store)
    gw = Gateway(ctrl, GatewayConfig(max_inflight_per_model=0))
    srv = GatewayHTTPServer(gw, HTTPConfig(port=0)).start()
    c = HTTPClient(srv.url())
    try:
        with pytest.raises(HTTPClientError) as e:
            c.complete(MODEL, [1], max_tokens=2)
        assert (e.value.status, e.value.code) == (
            429, ErrorCode.OVERLOADED)
        # stream requests see the same plain HTTP rejection, not SSE
        with pytest.raises(HTTPClientError) as e:
            list(c.complete(MODEL, [1], max_tokens=2, stream=True))
        assert e.value.status == 429
    finally:
        c.close()
        assert srv.stop(timeout_s=30.0)


def test_cancelled_maps_to_499(param_store):
    """Remote cancel: a non-stream request blocked decoding is aborted
    from a second connection and comes back as HTTP 499."""
    _, ctrl = _stack(param_store, n_nodes=1, min_replicas=1)
    srv = GatewayHTTPServer(Gateway(ctrl), HTTPConfig(port=0)).start()
    c = HTTPClient(srv.url())
    errors = []

    def blocked():
        try:
            c.complete(MODEL, [1, 2], max_tokens=140, timeout_s=60)
        except HTTPClientError as e:
            errors.append(e)

    t = threading.Thread(target=blocked)
    t.start()
    try:
        rid = None
        deadline = time.monotonic() + 30
        while rid is None and time.monotonic() < deadline:
            with srv._handles_lock:
                ids = list(srv._handles)
            rid = ids[0] if ids else None
            time.sleep(0.01)
        assert rid is not None
        c2 = HTTPClient(srv.url())
        assert c2.cancel(rid) is True
        t.join(timeout=30)
        assert not t.is_alive()
        assert len(errors) == 1
        assert (errors[0].status, errors[0].code) == (
            499, ErrorCode.CANCELLED)
        # cancelling a settled request 404s (handle untracked just
        # after the 499 is written; poll past that sliver)
        deadline = time.monotonic() + 10
        while True:
            try:
                assert c2.cancel(rid) is False   # done, still tracked
                assert time.monotonic() < deadline
                time.sleep(0.01)
            except HTTPClientError as e:
                assert e.status == 404
                break
        c2.close()
    finally:
        t.join(timeout=5)
        c.close()
        assert srv.stop(timeout_s=30.0)


def test_engine_failure_midstream_is_terminal_sse_error(param_store):
    """After the first streamed token a backend death surfaces as a
    terminal SSE error frame (engine_failed, code 500) followed by
    [DONE] — never a broken stream."""
    fleet, ctrl = _stack(param_store, n_nodes=1, min_replicas=1)
    srv = GatewayHTTPServer(Gateway(ctrl), HTTPConfig(port=0)).start()
    c = HTTPClient(srv.url())
    try:
        frames = []
        for chunk in c.complete(MODEL, [1, 2, 3], max_tokens=140,
                                stream=True, timeout_s=60):
            frames.append(chunk)
            if len([f for f in frames if "error" not in f
                    and f["choices"][0].get("token") is not None]) == 1:
                fleet.fail_node("h0")       # mid-stream outage
        assert "error" in frames[-1]        # terminal structured frame
        err = frames[-1]["error"]
        assert err["type"] == "engine_failed"
        assert err["code"] == 500
        # the SSE generator only returns on [DONE], so reaching here
        # proves the terminator followed the error frame
    finally:
        c.close()
        srv.stop(timeout_s=30.0)


# -------------------- basic surface -------------------------------- #
def test_healthz_and_models(client):
    health = client.healthz()
    assert health["status"] == "ok" and health["runtime_active"]
    entries = client.models_full()
    assert [m["id"] for m in entries] == [MODEL]
    assert entries[0]["family"] == "dense"
    assert entries[0]["replicas"] == 2
    assert entries[0]["max_context"] == 160


def test_http_greedy_matches_inprocess_gateway(server, client):
    """Acceptance: completion bytes over the socket == Gateway.generate
    for the same request."""
    prompt = [1, 2, 3, 4]
    out = client.complete(MODEL, prompt, max_tokens=8)
    resp = server.gateway.generate(MODEL, prompt,
                                   SamplingParams(max_tokens=8),
                                   timeout_s=60)
    assert resp.ok
    choice = out["choices"][0]
    assert choice["token_ids"] == list(resp.tokens)
    assert choice["text"] == decode_tokens(resp.tokens)
    assert choice["finish_reason"] == resp.finish_reason
    assert out["usage"] == {"prompt_tokens": 4, "completion_tokens": 8,
                            "total_tokens": 12}
    assert out["metadata"]["node"].startswith("h")


def test_text_prompt_encodes_with_model_vocab(client):
    out = client.complete(MODEL, "hi!", max_tokens=4)
    assert out["usage"]["prompt_tokens"] == len("hi!".encode())


def test_stream_final_chunks_carry_usage(client):
    """OpenAI parity: the terminal chunk of a completion stream and of a
    chat stream carries the `usage` object; token chunks never do."""
    chunks = list(client.complete(MODEL, [1, 2, 3], max_tokens=4,
                                  stream=True))
    final = chunks[-1]
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["usage"] == {"prompt_tokens": 3, "completion_tokens": 4,
                              "total_tokens": 7}
    assert all("usage" not in ch for ch in chunks[:-1])
    chat_chunks = list(client.chat(MODEL, ["hi"], max_tokens=4,
                                   stream=True))
    cfinal = chat_chunks[-1]
    assert cfinal["choices"][0]["finish_reason"] == "length"
    assert cfinal["usage"]["completion_tokens"] == 4
    assert cfinal["usage"]["prompt_tokens"] > 0      # templated prompt
    assert cfinal["usage"]["total_tokens"] == \
        cfinal["usage"]["prompt_tokens"] + 4
    assert all("usage" not in ch for ch in chat_chunks[:-1])


def test_sse_stream_framing(server):
    """Raw-socket SSE: ordered data frames, one finish chunk, then the
    literal `data: [DONE]` terminator."""
    conn = http.client.HTTPConnection("127.0.0.1", server.port,
                                      timeout=60)
    conn.request("POST", "/v1/completions", json.dumps({
        "model": MODEL, "prompt": [5, 6], "max_tokens": 6,
        "stream": True}), {"Content-Type": "application/json"})
    resp = conn.getresponse()
    assert resp.status == 200
    assert resp.headers["Content-Type"] == "text/event-stream"
    assert int(resp.headers["X-Request-Id"]) >= 0
    payloads = []
    while True:
        line = resp.readline().strip()
        if not line.startswith(b"data:"):
            continue
        data = line[len(b"data:"):].strip()
        payloads.append(data)
        if data == b"[DONE]":
            break
    conn.close()
    assert payloads[-1] == b"[DONE]"
    frames = [json.loads(p) for p in payloads[:-1]]
    tokens = [f["choices"][0] for f in frames
              if f["choices"][0].get("token") is not None]
    assert [t["token_index"] for t in tokens] == list(range(6))
    finals = [f for f in frames if f["choices"][0]["finish_reason"]]
    assert len(finals) == 1                 # exactly one terminal chunk
    assert finals[0]["choices"][0]["finish_reason"] == "length"
    assert frames[-1] is finals[0]          # ... and it precedes [DONE]


def test_chat_stream_role_then_deltas(client):
    frames = list(client.chat(MODEL, ["hello"], max_tokens=5,
                              stream=True))
    assert frames[0]["choices"][0]["delta"]["role"] == "assistant"
    toks = [f["choices"][0]["delta"] for f in frames
            if f["choices"][0].get("delta", {}).get("token") is not None]
    assert len(toks) == 5
    assert [d["token_index"] for d in toks] == list(range(5))
    assert frames[-1]["choices"][0]["finish_reason"] == "length"


def test_stream_tokens_match_nonstream(client):
    streamed = [f["choices"][0]["token"]
                for f in client.complete(MODEL, [9, 8, 7], max_tokens=6,
                                         stream=True)
                if f["choices"][0].get("token") is not None]
    flat = client.complete(MODEL, [9, 8, 7], max_tokens=6)
    assert streamed == flat["choices"][0]["token_ids"]


def test_validation_errors(client):
    for body_err in (
            {"prompt": [1], "max_tokens": 0},
            {"prompt": [1], "temperature": -1.0},
            {"prompt": [1], "top_p": 0.0},
            {"prompt": [1], "n": 2},
            {"prompt": [1, "x"]},
            {"prompt": [1], "timeout_s": 0},
    ):
        with pytest.raises(HTTPClientError) as e:
            client.complete(MODEL, body_err.pop("prompt"), max_tokens=2,
                            extra=body_err)
        assert e.value.status == 400, body_err
    with pytest.raises(HTTPClientError) as e:
        client.chat(MODEL, [{"role": "alien", "content": "hi"}])
    assert e.value.status == 400
    with pytest.raises(HTTPClientError) as e:
        client.chat(MODEL, [])
    assert e.value.status == 400


def test_unknown_route_and_method(server):
    conn = http.client.HTTPConnection("127.0.0.1", server.port)
    conn.request("GET", "/v2/everything")
    resp = conn.getresponse()
    assert resp.status == 404
    resp.read()                    # keep-alive: drain before reuse
    conn.request("GET", "/v1/completions")
    resp = conn.getresponse()
    assert resp.status == 405
    resp.read()
    conn.close()


# -------------------- chat templates ------------------------------- #
def test_template_registry_resolution():
    assert template_for("llama3.2-1b") is LLAMA3
    assert template_for("llama3.2-1b-reduced") is LLAMA3
    assert template_for("gemma3-4b") is GEMMA
    assert template_for("qwen3-8b") is CHATML
    assert template_for("deepseek-r1-7b") is CHATML
    assert template_for("mystery-model") is PLAIN


def test_chat_template_golden_renders():
    msgs = [ChatMessage("system", "be brief"), ChatMessage("user", "hi")]
    assert LLAMA3.render_text(msgs) == (
        "<|begin_of_text|>"
        "<|start_header_id|>system<|end_header_id|>\n\nbe brief<|eot_id|>"
        "<|start_header_id|>user<|end_header_id|>\n\nhi<|eot_id|>"
        "<|start_header_id|>assistant<|end_header_id|>\n\n")
    assert GEMMA.render_text(msgs) == (
        "<bos>"
        "<start_of_turn>system\nbe brief<end_of_turn>\n"
        "<start_of_turn>user\nhi<end_of_turn>\n"
        "<start_of_turn>model\n")
    assert CHATML.render_text(msgs) == (
        "<|im_start|>system\nbe brief<|im_end|>\n"
        "<|im_start|>user\nhi<|im_end|>\n"
        "<|im_start|>assistant\n")
    assert PLAIN.render_text(msgs) == (
        "system: be brief\nuser: hi\nassistant:")
    # assistant -> model rename is gemma-only
    turn = [ChatMessage("assistant", "ok")]
    assert "<start_of_turn>model\nok" in GEMMA.render_text(turn)
    assert "assistant\nok" in CHATML.render_text(turn)


def test_vision_models_get_image_marker_and_prefix_budget():
    from repro.api.http import prefix_budget
    vlm = ZOO["gemma3-4b"].reduced()            # frontend="vision"
    assert prefix_budget(vlm) > 0
    msgs = [ChatMessage("user", "what is this?")]
    with_marker = render_prompt(vlm.name, msgs, vlm)
    text = GEMMA.render_text(msgs, vision=True)
    assert with_marker == encode_text(text, vlm.vocab)
    assert "<start_of_image>" in text
    # non-vision render of the same family omits the marker
    dense = ZOO["gemma3-1b"].reduced()
    assert "<start_of_image>" not in GEMMA.render_text(msgs)
    assert len(render_prompt(dense.name, msgs, dense)) < len(with_marker)


def test_codec_roundtrip():
    text = "hello ☃ world"
    toks = encode_text(text, 256)
    assert decode_tokens(toks) == text
    assert decode_tokens([72, 105, 9999]) == "Hi�"


# -------------------- tenancy over keep-alive ---------------------- #
def test_concurrent_keepalive_tenants_one_rate_limited(server, client):
    """Two tenants on concurrent keep-alive connections: the capped one
    sees 429 RATE_LIMITED mid-burst, the free one never does."""
    client.set_tenant_quota("ka-capped", requests_per_s=0.001,
                            burst_requests=2)
    results = {}

    def worker(tenant):
        c = HTTPClient(server.url(), tenant=tenant)
        ok, limited, other = 0, 0, []
        first = c.healthz()                      # open the connection
        sock = c._conn.sock
        for i in range(5):
            try:
                c.complete(MODEL, [1, 2, i + 1], max_tokens=3,
                           timeout_s=60)
                ok += 1
            except HTTPClientError as e:
                if e.code is ErrorCode.RATE_LIMITED:
                    limited += 1
                else:
                    other.append(e)
        reused = c._conn is not None and c._conn.sock is sock
        results[tenant] = (ok, limited, other, reused, first)
        c.close()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in ("ka-free", "ka-capped")]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive()
    ok, limited, other, reused, _ = results["ka-free"]
    assert (ok, limited, other) == (5, 0, [])
    assert reused                       # keep-alive: one socket, 6 calls
    ok, limited, other, reused, _ = results["ka-capped"]
    assert ok == 2 and limited == 3 and other == []
    assert reused                       # 429s ride the same connection
    client.remove_tenant_quota("ka-capped")


def test_tenant_quota_admin_roundtrip(client):
    client.set_tenant_quota("acme", requests_per_s=7, tokens_per_s=100)
    quotas = client.tenant_quotas()
    assert quotas["acme"]["requests_per_s"] == 7
    assert quotas["acme"]["tokens_per_s"] == 100
    client.remove_tenant_quota("acme")
    assert "acme" not in client.tenant_quotas()


# -------------------- stale-connection retry ----------------------- #
class _FakeSock:
    def __init__(self):
        self.data = b""

    def sendall(self, b):
        self.data += b

    def close(self):
        pass


class _FlakyConn:
    """Connection whose first `request` dies with OSError — optionally
    after pushing bytes onto the wire (the stale keep-alive case)."""

    def __init__(self, send_bytes=True):
        self.sock = None
        self.attempts = 0
        self._failed = False
        self._send = send_bytes

    def connect(self):
        self.sock = _FakeSock()

    def request(self, method, path, body=None, headers=None):
        self.attempts += 1
        if not self._failed:
            self._failed = True
            if self._send:
                self.sock.sendall(b"POST /x HTTP/1.1\r\n")
            raise OSError(104, "connection reset by peer")
        self.sock.sendall(b"ok")

    def getresponse(self):
        class _R:
            status = 200
            headers = {}

            def read(self):
                return b"{}"
        return _R()

    def close(self):
        self.sock = None


def _patched_client(conn):
    c = HTTPClient("http://127.0.0.1:1")
    c._connection = lambda: conn
    return c


def test_post_with_bytes_on_wire_is_not_retried():
    """A send error after request bytes reached the socket may still
    have delivered the whole request — blind-retrying a generation POST
    there could double-submit and double-charge it, so the client must
    surface the error instead."""
    conn = _FlakyConn(send_bytes=True)
    with pytest.raises(OSError):
        _patched_client(conn)._json("POST", "/v1/completions", {"x": 1})
    assert conn.attempts == 1


def test_get_and_zero_byte_post_failures_are_retried():
    """Idempotent GETs always retry once; a POST whose send died before
    any byte left the client cannot have been acted on, so it retries
    too."""
    conn = _FlakyConn(send_bytes=True)
    assert _patched_client(conn)._json("GET", "/healthz") == {}
    assert conn.attempts == 2
    conn = _FlakyConn(send_bytes=False)
    assert _patched_client(conn)._json("POST", "/v1/x", {"x": 1}) == {}
    assert conn.attempts == 2


# -------------------- admin over the wire -------------------------- #
def test_admin_cache_flush_over_wire(client):
    """The flush verb round-trips; engines deployed without a prefix
    cache report zero flushed/remaining."""
    res = client.admin_cache_flush()
    assert res == {"flushed": 0, "remaining": 0}
    res = client.admin_cache_flush(MODEL)
    assert set(res) == {"flushed", "remaining"}


def test_admin_snapshot_and_scale(client):
    snap = client.admin_snapshot()
    assert snap["connected"] == 2
    assert snap["models"][MODEL] == 2
    assert client.admin_scale(MODEL, 2)["ok"]       # no-op at target
    with pytest.raises(HTTPClientError) as e:
        client.admin_deploy("never-registered")
    assert e.value.status == 400


# -------------------- lifecycle ------------------------------------ #
def test_stop_drains_inflight_stream(param_store):
    """stop(drain=True) lets an open SSE stream finish ([DONE] arrives)
    before the server parks, then refuses new connections."""
    _, ctrl = _stack(param_store)
    srv = GatewayHTTPServer(Gateway(ctrl), HTTPConfig(port=0)).start()
    url = srv.url()
    c = HTTPClient(url)
    frames = []
    stream = c.complete(MODEL, [1, 2], max_tokens=40, stream=True,
                        timeout_s=60)
    frames.append(next(stream))                  # stream is live
    stopped = {}
    t = threading.Thread(
        target=lambda: stopped.update(ok=srv.stop(timeout_s=60.0)))
    t.start()
    frames.extend(stream)                        # drain to [DONE]
    t.join(timeout=90)
    assert not t.is_alive() and stopped["ok"] is True
    toks = [f for f in frames
            if f["choices"][0].get("token") is not None]
    assert len(toks) == 40                       # nothing truncated
    assert frames[-1]["choices"][0]["finish_reason"] == "length"
    c.close()
    with pytest.raises((ConnectionRefusedError, HTTPClientError, OSError)):
        HTTPClient(url).healthz()


def test_deprecated_client_shim_warns(param_store):
    from repro.core import Client
    _, ctrl = _stack(param_store, n_nodes=1, min_replicas=1)
    with pytest.warns(DeprecationWarning, match="Gateway"):
        shim = Client(ctrl)
    req = shim.generate(MODEL, [1, 2], SamplingParams(max_tokens=3))
    assert len(req.output) == 3                  # still functional


# -------------------- CLI ------------------------------------------ #
def test_cli_models_complete_and_stream(server, capsys):
    from repro.api.http.client import _main
    url = server.url()
    assert _main(["--url", url, "models"]) == 0
    out = capsys.readouterr().out
    assert MODEL in out and "replicas=2" in out
    assert _main(["--url", url, "complete", MODEL, "1,2,3", "--tokens",
                  "--max-tokens", "4"]) == 0
    body = json.loads(capsys.readouterr().out)
    assert len(body["choices"][0]["token_ids"]) == 4
    assert _main(["--url", url, "chat", MODEL, "hello",
                  "--max-tokens", "3", "--stream"]) == 0
    assert "[finish] length" in capsys.readouterr().out
    assert _main(["--url", url, "health"]) == 0
    assert json.loads(capsys.readouterr().out)["status"] == "ok"

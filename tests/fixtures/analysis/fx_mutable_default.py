"""Seeded violation: a shared mutable default argument."""


def collect(item, acc=[]):              # shared across calls: flagged
    acc.append(item)
    return acc

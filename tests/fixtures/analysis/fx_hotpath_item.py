"""Seeded violation: a `.item()` host sync inside the engine step hot
path (the checker roots reachability at InferenceEngine.step)."""


class InferenceEngine:
    def step(self):
        logits = self._forward()
        return logits.item()            # device->host sync: flagged

    def _forward(self):
        return None

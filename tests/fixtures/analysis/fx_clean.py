"""Fixture every checker passes: guarded state, canonical-only lock
nesting, immutable defaults, no host syncs, no unpaired retains."""
import threading


class CleanCounter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def add(self, n):
        with self._lock:
            self.total += n

    def snapshot(self):
        with self._lock:
            return self.total


class CleanWalker:
    def __init__(self, node, inst):
        self.node = node
        self.inst = inst

    def walk(self):
        with self.node.lock:            # node -> instance: canonical
            with self.inst.lock:
                return self.inst.engine


def merge(items, extra=()):
    out = list(items)
    out.extend(extra)
    return out

"""Seeded violation: scheduler -> node nesting inverts the canonical
node -> instance -> scheduler hierarchy.  test_analysis asserts the
lock-order checker flags `rebalance`."""
import threading


class BadPlanner:
    def __init__(self, node, sched):
        self.node = node
        self.sched = sched
        self._audit = threading.Lock()

    def rebalance(self):
        with self.sched._lock:          # scheduler (rank 2) held ...
            with self.node.lock:        # ... node (rank 0): inversion
                return list(self.node.instances)

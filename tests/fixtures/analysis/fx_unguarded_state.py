"""Seeded violation: `total` is written under `_lock` in `add` but
lock-free in `sloppy_add` — the shared-state checker must flag the
bare write."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def add(self, n):
        with self._lock:
            self.total += n

    def sloppy_add(self, n):
        self.total += n                 # lock-free write: flagged

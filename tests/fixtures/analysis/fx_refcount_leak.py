"""Seeded violation: `retain(page)` with an exception exit before any
release/record — the refcount-pairing checker must flag the leak."""


class LeakyCache:
    def __init__(self, pool):
        self.pool = pool
        self._entries = {}

    def put(self, key, page):
        self.pool.retain(page)
        if key in self._entries:
            raise KeyError(key)         # retained page leaks: flagged
        self._entries[key] = page
